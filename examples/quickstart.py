"""Quickstart: a batch of group-by aggregates over a join, the LMFAO way.

    PYTHONPATH=src python examples/quickstart.py

Builds a small Favorita-like database (6 relations, star schema — paper
Fig. 3), opens a session with ``repro.connect``, declares a batch of
aggregate queries in the paper's Q(F; α) form, registers them as named
views (one compile through the engine's layers: join tree -> roots ->
directional views -> merging -> view groups -> multi-output jit plans),
and runs them.  The session's :class:`repro.ExecutionConfig` is the ONE
place execution policy lives — swap ``backend="pallas"``, set a mesh, or
pass ``maintain=True`` to the same ``views()`` call for incremental
maintenance, without changing any of the code below.
"""

import os

import numpy as np

import repro
from repro.core import COUNT, Delta, Var, agg, query, sum_of, sum_prod
from repro.data import DeltaBatchUpdate
from repro.data import datasets as D

SCALE = float(os.environ.get("EXAMPLES_SCALE", "0.1"))


def main():
    ds = D.make("favorita", scale=SCALE)
    print(f"database: {ds.db.total_tuples():,} tuples across "
          f"{len(ds.tables)} relations")

    queries = [
        # Q1: total units sold (paper Example 3.1 shape)
        query("total_units", [], [sum_of("units")]),
        # Q2: per-family oil-price-weighted sales (Example 3.2 shape)
        query("by_family", ["family"], [COUNT, sum_of("units"),
                                        sum_prod("units", "price")]),
        # Q3: covar-style entries (eq. 2-4)
        query("cm_units_txns", [], [sum_prod("units", "txns")]),
        query("cm_by_city", ["city"], [sum_of("units")]),
        query("cm_city_family", ["city", "family"], [COUNT]),
        # Q4: a decision-tree-node aggregate (eq. 8): promo items only
        query("rt_node", [], [agg(Delta("promo", "==", 1)),
                              agg(Var("units"), Delta("promo", "==", 1))]),
    ]

    # one session: schema + join tree + resident relations + frozen config
    db = repro.connect(ds, config=repro.ExecutionConfig(backend="xla",
                                                        block_size=4096))
    views = db.views(queries)                 # compile once, names = queries
    print("registered views:", ", ".join(views.names))
    print(views.explain().summary())

    out = views.run()                         # one fused device dispatch
    print(f"total_units = {float(out['total_units'][0]):,.0f}")
    bf = np.asarray(out["by_family"])
    print(f"by_family: {bf.shape[0]} families; "
          f"busiest family sold {bf[:, 1].max():,.0f} units")
    print(f"covar(units, txns) = {float(out['cm_units_txns'][0]):,.0f}")
    print(f"promo rows = {float(out['rt_node'][..., 0]):,.0f}, "
          f"promo units = {float(out['rt_node'][..., 1]):,.0f}")

    # same queries, same session — but live under updates: maintain=True
    live = db.views(queries, maintain=True)
    live.run()                                # full scan -> epoch 0
    fact = ds.tables[ds.fact]
    pick = np.random.default_rng(0).integers(0, len(fact["units"]), 64)
    live.apply(DeltaBatchUpdate().insert(
        ds.fact, {a: np.asarray(c)[pick] for a, c in fact.items()}))
    print(f"after one 64-row insert batch: epoch={live.maintained.epoch}, "
          f"total_units = {float(live.results()['total_units'][0]):,.0f}")
    print(live.explain().summary())


if __name__ == "__main__":
    main()
