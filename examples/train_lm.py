"""End-to-end LM training driver (~100M params, a few hundred steps).

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --quick    # tiny, 60 steps

Exercises the full training substrate: deterministic pipeline, AdamW + cosine
schedule, chunked CE, fault-tolerant loop with checkpoints (kill it mid-run
and rerun — it resumes bit-identically).
"""

import argparse
import sys

from repro.launch import train as train_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    if args.quick:
        argv = ["--arch", args.arch, "--smoke", "--steps", "60",
                "--batch", "8", "--seq", "64", "--lr", "3e-3",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "20"]
    else:
        # ~100M params: d=768, 12L, ff=2048, vocab=32000 (tied embeddings)
        # a few hundred steps; resumable mid-run (CPU: ~10s/step)
        argv = ["--arch", args.arch, "--smoke", "--steps", "200",
                "--batch", "8", "--seq", "128", "--lr", "1e-3",
                "--d-model", "768", "--layers", "12", "--heads", "12",
                "--d-ff", "2048", "--vocab", "32000",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"]
    loop = train_launch.main(argv)
    losses = loop.losses()
    assert losses[-1] < losses[0], "loss should decrease"
    print(f"[example] OK: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
