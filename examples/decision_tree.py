"""CART decision trees over joins via dynamic aggregate batches (paper §2).

    PYTHONPATH=src python examples/decision_tree.py

One compiled batch serves every node of the tree: node conditions are mask
parameters of dynamic UDAFs (the paper recompiles C++ per node; traced JAX
params make that free).
"""

import os
import time

import numpy as np

from repro.core.plan import materialize_join
from repro.data import datasets as D
from repro.ml.trees import DecisionTree

SCALE = float(os.environ.get("EXAMPLES_SCALE", "0.2"))


def main():
    ds = D.make("favorita", scale=SCALE)
    t0 = time.time()
    dt = DecisionTree(ds, task="regression", max_depth=4, min_instances=100,
                      max_nodes=31).fit()
    t = time.time() - t0
    print(f"regression tree: {len(dt.nodes)} nodes ({dt.n_split_nodes()} splits) "
          f"in {t:.1f}s — one compiled batch, {dt.n_aggregates} aggregates/node")

    J = materialize_join(ds.schema, ds.tables,
                         order=["Oil", "Transactions", "Stores", "Sales",
                                "Holiday", "Items"])
    y = np.asarray(J[ds.label], np.float64)
    pred = dt.predict(J)
    print(f"rmse={np.sqrt(np.mean((pred - y) ** 2)):.4f} vs "
          f"predict-mean={np.std(y):.4f}")

    print("tree structure:")
    for node in dt.nodes:
        ind = "  " * node.depth
        if node.is_leaf:
            print(f"{ind}leaf n={node.n:,.0f} pred={node.prediction:.2f}")
        else:
            print(f"{ind}{node.feature} {'<=' if node.kind == 'ordered' else '=='} "
                  f"bucket {node.threshold}")

    # classification over TPC-DS (paper Table 5)
    ds2 = D.make("tpcds", scale=min(SCALE, 0.1))
    ct = DecisionTree(ds2, task="classification", label="c_preferred",
                      max_depth=3, min_instances=100, max_nodes=15).fit()
    J2 = materialize_join(ds2.schema, ds2.tables,
                          order=["customer_demographics", "customer",
                                 "household_demographics", "customer_address",
                                 "store_sales", "date_dim", "time_dim", "item",
                                 "store", "promotion"])
    acc = (ct.predict(J2).astype(int) == np.asarray(J2["c_preferred"])).mean()
    maj = max(np.asarray(J2["c_preferred"]).mean(),
              1 - np.asarray(J2["c_preferred"]).mean())
    print(f"classification tree accuracy={acc:.3f} (majority={maj:.3f})")


if __name__ == "__main__":
    main()
