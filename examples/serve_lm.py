"""Batched serving example: greedy decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch llama3-8b
"""

import argparse

from repro.launch import serve as serve_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    args = ap.parse_args()
    serve_launch.main(["--arch", args.arch, "--batch", "4",
                       "--prompt-len", "8", "--gen", "24"])


if __name__ == "__main__":
    main()
