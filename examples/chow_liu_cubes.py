"""Chow-Liu structure learning + data cubes from one engine (paper §2).

    PYTHONPATH=src python examples/chow_liu_cubes.py
"""

import os
import time

from repro.data import datasets as D
from repro.ml.chowliu import chow_liu
from repro.ml.cubes import cube_name, cube_rollup, cube_via_engine

SCALE = float(os.environ.get("EXAMPLES_SCALE", "0.1"))


def main():
    ds = D.make("favorita", scale=SCALE)

    t0 = time.time()
    res = chow_liu(ds, attrs=["city", "state", "stype", "cluster", "family",
                              "htype", "locale"])
    print(f"chow-liu over {len(res.attrs)} attributes "
          f"({res.n_aggregates} count queries) in {time.time() - t0:.1f}s")
    print("learned tree edges (by mutual information):")
    for a, b in res.edges:
        i, j = res.attrs.index(a), res.attrs.index(b)
        print(f"  {a} -- {b}   MI={res.mi[i, j]:.4f}")

    dims, meas = ["stype", "locale", "family"], ["units", "txns"]
    t0 = time.time()
    cube = cube_via_engine(ds, dims, meas)
    t_engine = time.time() - t0
    t0 = time.time()
    rolled = cube_rollup(ds, dims, meas)
    t_roll = time.time() - t0
    print(f"\n3-d data cube ({2 ** len(dims)} group-bys x {len(meas)} measures): "
          f"engine={t_engine:.2f}s lattice-rollup={t_roll:.2f}s")
    total = cube[cube_name([])]
    print(f"ALL cell: units={total[0]:,.0f} txns={total[1]:,.0f}")
    print(f"finest cell shape: {cube[cube_name(dims)].shape}")
    import numpy as np
    for k in cube:
        assert np.allclose(cube[k], rolled[k], rtol=1e-4, atol=1e-3)
    print("engine path == lattice rollup ✓")


if __name__ == "__main__":
    main()
