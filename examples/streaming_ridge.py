"""Streaming ridge regression: model refresh that scales with the update.

    PYTHONPATH=src python examples/streaming_ridge.py

Fits ridge over the Favorita join once (full scan), then streams insert/
delete batches against the fact table.  Each tick folds the delta into the
maintained covar views (`core/ivm.py`) and re-solves the closed form —
compare the per-tick cost against recomputing the whole aggregate batch.
"""

import os
import time

import numpy as np

from repro.data import datasets as D
from repro.ml.online import OnlineRidge

SCALE = float(os.environ.get("EXAMPLES_SCALE", "0.2"))


def main():
    ds = D.make("favorita", scale=SCALE)
    olr = OnlineRidge(ds)

    t0 = time.time()
    olr.fit()
    t_fit = time.time() - t0
    mb = olr.maintained
    print(f"fit: N={olr.N:,.0f}, p={olr.layout.p} features, "
          f"{mb.batch.stats.summary()}  [{t_fit:.2f}s]")
    dp = mb.delta_program(ds.fact)
    print(f"delta program for {ds.fact}: {dp.summary()}")

    rng = np.random.default_rng(0)
    fact = ds.tables[ds.fact]
    n = ds.db.relation(ds.fact).n_rows
    k = max(n // 100, 1)          # 1% churn per tick

    for tick in range(5):
        pick = rng.integers(0, n, k)
        t0 = time.time()
        olr.update_fact(
            inserts={a: np.asarray(c)[pick] for a, c in fact.items()},
            delete_idx=rng.choice(n, k, replace=False))
        t_up = time.time() - t0
        drift = float(np.linalg.norm(olr.theta))
        print(f"tick {tick}: {2 * k} delta tuples folded in {t_up * 1e3:.1f}ms "
              f"(‖θ‖={drift:.4f}, step={mb.step})")

    t0 = time.time()
    full = mb.batch(mb.db)
    t_full = time.time() - t0
    got = mb.results()
    worst = max(
        float(np.max(np.abs(np.asarray(got[q], np.float64) - np.asarray(full[q], np.float64)))
              / max(np.max(np.abs(np.asarray(full[q], np.float64))), 1.0))
        for q in got)
    print(f"full recompute for comparison: {t_full * 1e3:.1f}ms "
          f"(maintained vs fresh max rel err={worst:.2e})")

    # serving: pin an epoch, fold an update behind the pinned reader, and
    # show the snapshot stays frozen while fresh reads see the new epoch
    with mb.pinned() as epoch:
        pinned = np.asarray(mb.results(epoch=epoch)["cm_scalar"]).copy()
        pick = rng.integers(0, n, k)
        olr.update_fact(
            inserts={a: np.asarray(c)[pick] for a, c in fact.items()},
            delete_idx=rng.choice(n, k, replace=False))
        drift = float(np.max(np.abs(
            np.asarray(mb.results()["cm_scalar"]) - pinned)))
        frozen = np.array_equal(
            pinned, np.asarray(mb.results(epoch=epoch)["cm_scalar"]))
        print(f"epoch {epoch} pinned while epoch {mb.epoch} published: "
              f"snapshot frozen={frozen}, current drifted by {drift:.3g}")


if __name__ == "__main__":
    main()
