"""Ridge regression over a join without materializing it (paper §4.2).

    PYTHONPATH=src python examples/ridge_over_joins.py

Computes the covar-matrix batch with the engine, trains by BGD with
Armijo/Barzilai-Borwein over the (tiny) sufficient statistics, cross-checks
against the closed-form solution, and evaluates RMSE on held-out rows.
"""

import os
import time

import numpy as np

from repro.core.plan import materialize_join
from repro.data import datasets as D
from repro.ml import ridge
from repro.ml.covar import compute_covar

SCALE = float(os.environ.get("EXAMPLES_SCALE", "0.2"))


def main():
    ds = D.make("retailer", scale=SCALE)
    t0 = time.time()
    C, N, layout, batch = compute_covar(ds)
    t_agg = time.time() - t0
    print(f"covar: p={layout.p} features, N={N:,.0f} join rows, "
          f"{batch.stats.summary()}  [{t_agg:.2f}s]")

    t0 = time.time()
    res = ridge.bgd(C, N, layout, lam=1e-3)
    t_opt = time.time() - t0
    th_cf = ridge.closed_form(C, N, layout, lam=1e-3)
    print(f"BGD: {res.iterations} iters in {t_opt:.3f}s "
          f"(convergence is ~free next to the aggregates — the paper's point)")

    J = materialize_join(ds.schema, ds.tables,
                         order=["Census", "Location", "Weather", "Inventory",
                                "Items"])
    base = float(np.std(np.asarray(J[ds.label])))
    print(f"rmse: bgd={ridge.rmse(res.theta, layout, J):.4f} "
          f"closed-form={ridge.rmse(th_cf, layout, J):.4f} "
          f"predict-mean-baseline={base:.4f}")


if __name__ == "__main__":
    main()
