#!/usr/bin/env python
"""Ratchet a committed perf baseline (the perf gate's anchor).

Copies a fresh payload (by default the one in the working directory, or
regenerates it first with ``--run``) over its committed baseline under
``benchmarks/baselines/`` after validating its shape.  Default is the
kernel-roofline baseline (``BENCH_kernels.json``); ``--ivm`` ratchets the
IVM/sharded baseline (``BENCH_ivm.json``), ``--serving`` the
sustained-load serving baseline (``BENCH_serving.json``), and
``--routing`` the ad-hoc routing baseline (``BENCH_routing.json``)
instead.  Commit the result deliberately — the diff IS the
perf-trajectory claim the CI gate (``tools/perf_gate.py``) enforces from
then on.

    BENCH_SCALE=0.01 PYTHONPATH=src python tools/update_perf_baseline.py --run
    BENCH_SCALE=0.01 PYTHONPATH=src python tools/update_perf_baseline.py --run --ivm
    BENCH_SCALE=0.01 PYTHONPATH=src python tools/update_perf_baseline.py --run --serving
    BENCH_SCALE=0.01 PYTHONPATH=src python tools/update_perf_baseline.py --run --routing
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_DST = os.path.join(REPO, "benchmarks", "baselines",
                           "BENCH_kernels.json")
DEFAULT_DST_IVM = os.path.join(REPO, "benchmarks", "baselines",
                               "BENCH_ivm.json")
DEFAULT_DST_SERVING = os.path.join(REPO, "benchmarks", "baselines",
                                   "BENCH_serving.json")
DEFAULT_DST_ROUTING = os.path.join(REPO, "benchmarks", "baselines",
                                   "BENCH_routing.json")


def validate(payload: dict) -> None:
    for key in ("peaks", "kernels", "e2e"):
        if key not in payload:
            raise SystemExit(f"refusing to ratchet: payload missing {key!r}")
    for name, e in payload["e2e"].items():
        if "speedup_fused_auto" not in e:
            raise SystemExit(f"refusing to ratchet: e2e/{name} missing "
                             "speedup_fused_auto")
        if not e.get("allclose_xla"):
            raise SystemExit(f"refusing to ratchet: e2e/{name} is not "
                             "allclose to the xla backend — fix correctness "
                             "before moving the perf anchor")


def validate_ivm(payload: dict) -> None:
    if payload.get("steady_state_retraces") != 0:
        raise SystemExit("refusing to ratchet: steady_state_retraces != 0 — "
                         "the resident tick is retracing; fix the jit cache "
                         "before moving the perf anchor")
    if not payload.get("sharded"):
        raise SystemExit("refusing to ratchet: payload missing sharded rows")
    for name, e in payload["sharded"].items():
        if e.get("steady_state_retraces") != 0:
            raise SystemExit(f"refusing to ratchet: sharded/{name} retraces "
                             "in steady state")
        if not e.get("allclose_local"):
            raise SystemExit(f"refusing to ratchet: sharded/{name} disagrees "
                             "with the single-device recompute — fix "
                             "correctness before moving the perf anchor")


def validate_serving(payload: dict) -> None:
    """The serving contract must hold before the wall numbers mean anything:
    a baseline captured from a broken run would gate future runs on
    garbage."""
    if payload.get("n_rejected_updates") != 0:
        raise SystemExit("refusing to ratchet: serving run rejected updates")
    if payload.get("n_reader_errors") != 0:
        raise SystemExit("refusing to ratchet: reader threads errored "
                         f"({payload.get('errors')}) — fix the concurrency "
                         "bug before moving the perf anchor")
    if not payload.get("read_count"):
        raise SystemExit("refusing to ratchet: zero reads recorded — the "
                         "latency distribution is degenerate")
    p50, p99 = payload.get("read_p50_us"), payload.get("read_p99_us")
    if not p50 or p99 is None or p99 < p50:
        raise SystemExit("refusing to ratchet: degenerate read latency "
                         f"distribution (p50={p50}, p99={p99})")
    if (payload.get("n_evictions") or 0) < 1:
        raise SystemExit("refusing to ratchet: eviction churn never "
                         "exercised (n_evictions == 0)")
    sigs = payload.get("served_view_signatures")
    n_views = payload.get("n_served_views")
    if sigs is None or n_views is None or sigs < n_views:
        raise SystemExit("refusing to ratchet: workload recorder missed "
                         f"served views ({sigs} signatures for {n_views} "
                         "views)")


def validate_routing(payload: dict) -> None:
    """Routing soundness must hold before the latency split means
    anything — a baseline captured from a drifting router would gate
    future runs on garbage."""
    for c in ("allclose_exact", "allclose_subsumed", "allclose_compiled"):
        if not payload.get(c):
            raise SystemExit(f"refusing to ratchet: {c} is false — a routed "
                             "answer disagrees with the from-scratch "
                             "compile; fix soundness before moving the "
                             "perf anchor")
    if payload.get("n_admission_failures") != 0:
        raise SystemExit("refusing to ratchet: the admission gate rejected "
                         "a router-compiled plan")
    if (payload.get("n_evictions") or 0) < 1 \
            or not payload.get("evicted_recompiles"):
        raise SystemExit("refusing to ratchet: LRU eviction churn never "
                         "exercised")
    if not payload.get("n_queries") or not payload.get("route_hit_rate"):
        raise SystemExit("refusing to ratchet: degenerate routed workload "
                         f"(n_queries={payload.get('n_queries')}, "
                         f"hit_rate={payload.get('route_hit_rate')})")


_MODES = {
    "kernels": ("BENCH_kernels.json", DEFAULT_DST, "bench_kernels",
                validate),
    "ivm": ("BENCH_ivm.json", DEFAULT_DST_IVM, "bench_ivm", validate_ivm),
    "serving": ("BENCH_serving.json", DEFAULT_DST_SERVING, "bench_serving",
                validate_serving),
    "routing": ("BENCH_routing.json", DEFAULT_DST_ROUTING, "bench_routing",
                validate_routing),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--src", default=None, help="fresh payload to promote")
    ap.add_argument("--dst", default=None)
    ap.add_argument("--ivm", action="store_true",
                    help="ratchet the IVM/sharded baseline (BENCH_ivm.json) "
                    "instead of the kernel roofline")
    ap.add_argument("--serving", action="store_true",
                    help="ratchet the sustained-load serving baseline "
                    "(BENCH_serving.json) instead of the kernel roofline")
    ap.add_argument("--routing", action="store_true",
                    help="ratchet the ad-hoc routing baseline "
                    "(BENCH_routing.json) instead of the kernel roofline")
    ap.add_argument("--run", action="store_true",
                    help="regenerate --src via the benchmark module before "
                    "promoting")
    args = ap.parse_args(argv)
    picked = [m for m, flag in
              [("ivm", args.ivm), ("serving", args.serving),
               ("routing", args.routing)] if flag]
    if len(picked) > 1:
        raise SystemExit("--ivm / --serving / --routing are mutually "
                         "exclusive")
    mode = picked[0] if picked else "kernels"
    default_src, default_dst, mod, validator = _MODES[mode]
    src = args.src or default_src
    dst = args.dst or default_dst

    if args.run:
        env = dict(os.environ)
        env.setdefault("PYTHONPATH", os.path.join(REPO, "src"))
        env["BENCH_JSON_OUT"] = src
        code = ("import json, os\n"
                f"from benchmarks import {mod}\n"
                f"{mod}.main()\n"
                "with open(os.environ['BENCH_JSON_OUT'], 'w') as f:\n"
                f"    json.dump({mod}.JSON_PAYLOAD, f, indent=1, "
                "sort_keys=True)\n")
        subprocess.run([sys.executable, "-c", code], check=True, env=env,
                       cwd=REPO)

    with open(src) as f:
        payload = json.load(f)
    validator(payload)
    os.makedirs(os.path.dirname(dst), exist_ok=True)
    with open(dst, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"baseline ratcheted: {src} -> {dst}")
    if mode == "ivm":
        for name, e in sorted(payload["sharded"].items()):
            print(f"  sharded/{name}: tick={e['tick_us_sharded']:.0f}us "
                  f"read={e['read_us_sharded']:.0f}us "
                  f"retraces={e['steady_state_retraces']}")
    elif mode == "serving":
        print(f"  serving: read_p50={payload['read_p50_us']:.0f}us "
              f"read_p99={payload['read_p99_us']:.0f}us "
              f"ticks/s={payload['ticks_per_s']:.1f} "
              f"evictions={payload['n_evictions']} "
              f"signatures={payload['served_view_signatures']}")
    elif mode == "routing":
        print(f"  routing: exact_p50={payload['route_exact_p50_us']:.0f}us "
              f"subsumed_p50={payload['route_subsumed_p50_us']:.0f}us "
              f"compile={payload['route_compile_us']:.0f}us "
              f"hit_rate={payload['route_hit_rate']:.3f} "
              f"evictions={payload['n_evictions']}")
    else:
        for name, e in payload["e2e"].items():
            print(f"  e2e/{name}: speedup_fused_auto="
                  f"{e['speedup_fused_auto']:.3f} "
                  f"launches={e['n_launches_fused']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
