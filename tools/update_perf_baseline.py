#!/usr/bin/env python
"""Ratchet the committed kernel-roofline baseline (the perf gate's anchor).

Copies a fresh ``BENCH_kernels.json`` (by default the one in the working
directory, or regenerates it first with ``--run``) over
``benchmarks/baselines/BENCH_kernels.json`` after validating its shape.
Commit the result deliberately — the diff IS the perf-trajectory claim the
CI gate (``tools/perf_gate.py``) enforces from then on.

    BENCH_SCALE=0.01 PYTHONPATH=src python tools/update_perf_baseline.py --run
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_DST = os.path.join(REPO, "benchmarks", "baselines",
                           "BENCH_kernels.json")


def validate(payload: dict) -> None:
    for key in ("peaks", "kernels", "e2e"):
        if key not in payload:
            raise SystemExit(f"refusing to ratchet: payload missing {key!r}")
    for name, e in payload["e2e"].items():
        if "speedup_fused_auto" not in e:
            raise SystemExit(f"refusing to ratchet: e2e/{name} missing "
                             "speedup_fused_auto")
        if not e.get("allclose_xla"):
            raise SystemExit(f"refusing to ratchet: e2e/{name} is not "
                             "allclose to the xla backend — fix correctness "
                             "before moving the perf anchor")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--src", default="BENCH_kernels.json",
                    help="fresh payload to promote")
    ap.add_argument("--dst", default=DEFAULT_DST)
    ap.add_argument("--run", action="store_true",
                    help="regenerate --src via benchmarks.bench_kernels "
                    "before promoting")
    args = ap.parse_args(argv)

    if args.run:
        env = dict(os.environ)
        env.setdefault("PYTHONPATH", os.path.join(REPO, "src"))
        env["BENCH_KERNELS_JSON"] = args.src
        code = ("import json, os\n"
                "from benchmarks import bench_kernels\n"
                "bench_kernels.main()\n"
                "with open(os.environ['BENCH_KERNELS_JSON'], 'w') as f:\n"
                "    json.dump(bench_kernels.JSON_PAYLOAD, f, indent=1, "
                "sort_keys=True)\n")
        subprocess.run([sys.executable, "-c", code], check=True, env=env,
                       cwd=REPO)

    with open(args.src) as f:
        payload = json.load(f)
    validate(payload)
    os.makedirs(os.path.dirname(args.dst), exist_ok=True)
    with open(args.dst, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"baseline ratcheted: {args.src} -> {args.dst}")
    for name, e in payload["e2e"].items():
        print(f"  e2e/{name}: speedup_fused_auto="
              f"{e['speedup_fused_auto']:.3f} "
              f"launches={e['n_launches_fused']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
