#!/usr/bin/env python
"""CI entry point for the engine-contract linter (DESIGN.md §12).

Thin wrapper over :mod:`repro.analysis.lint` that anchors paths at the repo
root, so ``python tools/lint_contracts.py`` works from any cwd and CI needs
no PYTHONPATH gymnastics.  Exits non-zero on any violation that survives
``tools/lint_allowlist.json``.
"""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.lint import main  # noqa: E402

if __name__ == "__main__":
    argv = sys.argv[1:] or [str(ROOT / "src")]
    sys.exit(main(argv + ["--root", str(ROOT),
                          "--allowlist",
                          str(ROOT / "tools" / "lint_allowlist.json")]))
