#!/usr/bin/env python
"""CI perf gate: diff a fresh benchmark payload against its committed
baseline and fail on regression beyond tolerance (ROADMAP item 5).

Understands both payload schemas — the gated sections are whatever the
baseline file carries:

* ``BENCH_kernels.json``: kernel rooflines + fused/autotuned e2e speedups.
* ``BENCH_ivm.json``: IVM tick/read latencies plus the sharded rows
  (per-mesh steady-state tick and serving read).  Contract fields gate
  hard — ``steady_state_retraces`` must stay 0 (a retrace in steady state
  is a jit-cache bug, not noise) and the sharded epochs must stay allclose
  to the single-device recompute; wall times gate loose.
* ``BENCH_serving.json``: the sustained-load serving stress
  (``benchmarks/bench_serving.py``).  Contract fields gate hard — zero
  rejected updates, zero reader-thread errors, eviction churn actually
  exercised, one recorded workload signature per served view, a
  non-degenerate latency distribution, and a non-empty trace export;
  p50/p99 read latency and ticks/s gate loose.
* ``BENCH_routing.json``: ad-hoc query routing
  (``benchmarks/bench_routing.py``).  Contract fields gate hard — every
  tier allclose to a from-scratch compile (a routed answer that drifts is
  a soundness bug, not noise), zero admission failures, LRU eviction
  exercised, and the workload hit rate within ``--ratio-tol`` of
  baseline; per-tier routed latencies gate loose.

Two classes of metric, gated differently:

* **machine-portable ratios** (the real trajectory claims) gate tight:
  each end-to-end ``speedup_fused_auto`` (autotuned+fused pallas vs
  static-block unfused) must stay within ``--ratio-tol`` of baseline AND
  above the ``--min-speedup`` hard floor; ``allclose_xla`` must hold; the
  static kernel-launch-site counts must not grow (launch fusion is a
  compile-time property — any increase is a code regression, not noise).

* **wall times** gate loose (``--time-tol``, default 1.5 → a kernel may be
  up to 2.5x slower than baseline before failing): CI runners vary, and the
  generous multiple only catches catastrophic regressions (an interpret-mode
  fallback on TPU, a lost jit cache, an accidentally quadratic path).

Refresh the baseline intentionally with ``tools/update_perf_baseline.py``
after a change that legitimately moves the numbers.

    python tools/perf_gate.py BENCH_kernels.json benchmarks/baselines/BENCH_kernels.json
"""

from __future__ import annotations

import argparse
import json
import sys


def check(current: dict, baseline: dict, *, time_tol: float,
          ratio_tol: float, min_speedup: float):
    """Yields (name, baseline_value, current_value, limit, ok) rows."""
    for name, base in sorted(baseline.get("kernels", {}).items()):
        cur = current.get("kernels", {}).get(name)
        if cur is None:
            yield (f"kernels/{name}/t_s", base["t_s"], None, "present", False)
            continue
        limit = base["t_s"] * (1.0 + time_tol)
        yield (f"kernels/{name}/t_s", base["t_s"], cur["t_s"],
               f"<= {limit:.3g}", cur["t_s"] <= limit)

    for name, base in sorted(baseline.get("e2e", {}).items()):
        cur = current.get("e2e", {}).get(name)
        if cur is None:
            yield (f"e2e/{name}", base.get("speedup_fused_auto"), None,
                   "present", False)
            continue
        floor = max(base["speedup_fused_auto"] * (1.0 - ratio_tol),
                    min_speedup)
        sp = cur["speedup_fused_auto"]
        yield (f"e2e/{name}/speedup_fused_auto", base["speedup_fused_auto"],
               sp, f">= {floor:.3g}", sp >= floor)
        yield (f"e2e/{name}/allclose_xla", base["allclose_xla"],
               cur["allclose_xla"], "== True", bool(cur["allclose_xla"]))
        yield (f"e2e/{name}/n_launches_fused", base["n_launches_fused"],
               cur["n_launches_fused"],
               f"<= {base['n_launches_fused']}",
               cur["n_launches_fused"] <= base["n_launches_fused"])

    # --- BENCH_ivm.json schema ---------------------------------------
    if "steady_state_retraces" in baseline:
        cur_r = current.get("steady_state_retraces")
        yield ("ivm/steady_state_retraces", baseline["steady_state_retraces"],
               cur_r, "== 0", cur_r == 0)
        for t in ("tick_us_resident", "delta_us"):
            if t not in baseline:
                continue
            cur_t = current.get(t)
            limit = baseline[t] * (1.0 + time_tol)
            yield (f"ivm/{t}", baseline[t], cur_t, f"<= {limit:.3g}",
                   cur_t is not None and cur_t <= limit)

    # --- BENCH_serving.json schema -----------------------------------
    if "ticks_per_s" in baseline:
        # contract fields: hard gates (concurrency bugs, not noise)
        for c in ("n_rejected_updates", "n_reader_errors"):
            yield (f"serving/{c}", baseline.get(c), current.get(c),
                   "== 0", current.get(c) == 0)
        n_views = current.get("n_served_views")
        sigs = current.get("served_view_signatures")
        yield ("serving/served_view_signatures",
               baseline.get("served_view_signatures"), sigs,
               f">= {n_views}",
               sigs is not None and n_views is not None and sigs >= n_views)
        yield ("serving/n_evictions", baseline.get("n_evictions"),
               current.get("n_evictions"), ">= 1",
               (current.get("n_evictions") or 0) >= 1)
        yield ("serving/trace_events", baseline.get("trace_events"),
               current.get("trace_events"), ">= 1",
               (current.get("trace_events") or 0) >= 1)
        p50 = current.get("read_p50_us")
        p99 = current.get("read_p99_us")
        yield ("serving/read_count", baseline.get("read_count"),
               current.get("read_count"), ">= 1",
               bool(current.get("read_count")))
        yield ("serving/read_p50_us_nonzero", baseline.get("read_p50_us"),
               p50, "> 0", p50 is not None and p50 > 0)
        yield ("serving/read_p99_ge_p50", baseline.get("read_p99_us"), p99,
               ">= p50",
               p99 is not None and p50 is not None and p99 >= p50)
        # wall times / throughput: loose gates (runner noise)
        for t in ("read_p50_us", "read_p99_us"):
            if t not in baseline:
                continue
            limit = baseline[t] * (1.0 + time_tol)
            cur_t = current.get(t)
            yield (f"serving/{t}", baseline[t], cur_t, f"<= {limit:.3g}",
                   cur_t is not None and cur_t <= limit)
        floor = baseline["ticks_per_s"] / (1.0 + time_tol)
        cur_tps = current.get("ticks_per_s")
        yield ("serving/ticks_per_s", baseline["ticks_per_s"], cur_tps,
               f">= {floor:.3g}",
               cur_tps is not None and cur_tps >= floor)

    # --- BENCH_routing.json schema -----------------------------------
    if "route_hit_rate" in baseline:
        # contract fields: hard gates (routing soundness, not noise)
        for c in ("allclose_exact", "allclose_subsumed",
                  "allclose_compiled", "evicted_recompiles"):
            yield (f"routing/{c}", baseline.get(c), current.get(c),
                   "== True", bool(current.get(c)))
        yield ("routing/n_admission_failures",
               baseline.get("n_admission_failures"),
               current.get("n_admission_failures"), "== 0",
               current.get("n_admission_failures") == 0)
        yield ("routing/n_evictions", baseline.get("n_evictions"),
               current.get("n_evictions"), ">= 1",
               (current.get("n_evictions") or 0) >= 1)
        hr_floor = baseline["route_hit_rate"] * (1.0 - ratio_tol)
        cur_hr = current.get("route_hit_rate")
        yield ("routing/route_hit_rate", baseline["route_hit_rate"], cur_hr,
               f">= {hr_floor:.3g}",
               cur_hr is not None and cur_hr >= hr_floor)
        # routed latencies: loose gates (runner noise)
        for t in ("route_exact_p50_us", "route_exact_p99_us",
                  "route_subsumed_p50_us", "route_subsumed_p99_us",
                  "route_cached_scan_p50_us", "route_cached_scan_p99_us",
                  "route_compile_us"):
            if t not in baseline:
                continue
            limit = baseline[t] * (1.0 + time_tol)
            cur_t = current.get(t)
            yield (f"routing/{t}", baseline[t], cur_t, f"<= {limit:.3g}",
                   cur_t is not None and cur_t <= limit)

    for name, base in sorted(baseline.get("sharded", {}).items()):
        cur = current.get("sharded", {}).get(name)
        if cur is None:
            yield (f"sharded/{name}", base["tick_us_sharded"], None,
                   "present", False)
            continue
        yield (f"sharded/{name}/steady_state_retraces",
               base["steady_state_retraces"], cur.get("steady_state_retraces"),
               "== 0", cur.get("steady_state_retraces") == 0)
        yield (f"sharded/{name}/allclose_local", base["allclose_local"],
               cur.get("allclose_local"), "== True",
               bool(cur.get("allclose_local")))
        for t in ("tick_us_sharded", "read_us_sharded"):
            limit = base[t] * (1.0 + time_tol)
            yield (f"sharded/{name}/{t}", base[t], cur.get(t),
                   f"<= {limit:.3g}",
                   cur.get(t) is not None and cur[t] <= limit)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh BENCH_kernels.json")
    ap.add_argument("baseline",
                    default="benchmarks/baselines/BENCH_kernels.json",
                    nargs="?", help="committed baseline")
    ap.add_argument("--time-tol", type=float, default=1.5,
                    help="allowed relative wall-time growth (1.5 -> 2.5x)")
    ap.add_argument("--ratio-tol", type=float, default=0.4,
                    help="allowed relative drop of speedup ratios")
    ap.add_argument("--min-speedup", type=float, default=0.9,
                    help="hard floor for fused-vs-static speedups")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failed = 0
    print(f"{'metric':<44} {'baseline':>12} {'current':>12} "
          f"{'limit':>12}  status")
    for name, base, cur, limit, ok in check(
            current, baseline, time_tol=args.time_tol,
            ratio_tol=args.ratio_tol, min_speedup=args.min_speedup):
        failed += not ok

        def fmt(v):
            if isinstance(v, bool):
                return str(v)
            if v is None:
                return "missing"
            return f"{v:.4g}"

        print(f"{name:<44} {fmt(base):>12} {fmt(cur):>12} {limit:>12}  "
              f"{'ok' if ok else 'FAIL'}")
    if failed:
        print(f"\nperf gate: {failed} metric(s) regressed beyond tolerance "
              "(refresh intentionally via tools/update_perf_baseline.py)")
        return 1
    print("\nperf gate: all metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
