"""CI smoke for the engine examples on the session API.

    PYTHONPATH=src EXAMPLES_SCALE=0.02 python tools/examples_smoke.py

Runs every ``examples/*.py`` aggregate-engine example in-process at small
scale and FAILS if any :class:`repro.core.engine.EngineDeprecationWarning`
fires — i.e. if an example, or anything inside the ``repro`` package it
calls, still routes through the deprecated ``Engine.compile`` /
``Engine.compile_incremental`` entry points instead of the facade.  (The
dedicated warning category keeps the gate sharp: third-party
DeprecationWarnings cannot trip it.)

The LM-seed examples (``train_lm.py``, ``serve_lm.py``) are out of scope —
they exercise the model-serving stack, not the aggregate engine.
"""

import os
import runpy
import sys
import time
import traceback
import warnings

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ENGINE_EXAMPLES = [
    "quickstart.py",
    "ridge_over_joins.py",
    "decision_tree.py",
    "chow_liu_cubes.py",
    "streaming_ridge.py",
]


def main() -> int:
    os.environ.setdefault("EXAMPLES_SCALE", "0.02")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.core.engine import EngineDeprecationWarning

    warnings.simplefilter("error", EngineDeprecationWarning)
    failed = []
    for name in ENGINE_EXAMPLES:
        path = os.path.join(REPO, "examples", name)
        t0 = time.time()
        print(f"=== {name} (EXAMPLES_SCALE={os.environ['EXAMPLES_SCALE']})",
              flush=True)
        try:
            runpy.run_path(path, run_name="__main__")
            print(f"=== {name} OK [{time.time() - t0:.1f}s]", flush=True)
        except EngineDeprecationWarning:
            traceback.print_exc()
            print(f"=== {name} FAILED: deprecated Engine entry point used "
                  "(port it to repro.connect / Database.views)", flush=True)
            failed.append(name)
        except Exception:
            traceback.print_exc()
            print(f"=== {name} FAILED", flush=True)
            failed.append(name)
    if failed:
        print(f"examples smoke: {len(failed)} failed: {', '.join(failed)}")
        return 1
    print(f"examples smoke: all {len(ENGINE_EXAMPLES)} passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
